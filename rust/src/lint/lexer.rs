//! Hand-rolled Rust lexer for the determinism lint.
//!
//! The container is offline and the crate is dependency-free, so
//! `detlint` cannot lean on `syn`.  It does not need to: the D1–D4
//! rules only consult the *token stream* (identifiers, punctuation,
//! string-literal contents) plus the line comments (for the
//! `// detlint: allow(..)` grammar).  This lexer therefore produces
//! exactly that — a flat token list with line numbers — and is careful
//! about the only genuinely tricky parts of Rust's lexical grammar:
//!
//! * `//` line comments and *nested* `/* */` block comments are
//!   skipped (line comments are captured for allow parsing);
//! * string literals (plain, byte, and raw with any `#` count) are
//!   emitted as [`TokKind::Str`] tokens carrying their contents, so
//!   the D4 registry cross-reference can match names while D1–D3 can
//!   never fire on text inside a string;
//! * lifetimes (`'a`) are distinguished from char literals (`'x'`,
//!   `'\n'`) so an apostrophe never desynchronizes the stream.
//!
//! Numeric literals are folded into a single [`TokKind::Num`] token;
//! every other non-whitespace character becomes a one-character
//! [`TokKind::Punct`] token (`::` is two `:` tokens — the rules match
//! on that shape).

/// Token class; see module docs for what each carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String literal; `text` holds the *contents* (delimiters and any
    /// raw-string hashes stripped, escapes left as written).
    Str,
    /// Char or byte-char literal (contents, no quotes).
    Char,
    /// Lifetime, without the leading apostrophe.
    Lifetime,
    Num,
    /// Single punctuation character.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    /// Is this the identifier `s`?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// Lexer output: the token stream plus captured `//` comments
/// (1-based line, text after the `//`).
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub line_comments: Vec<(usize, String)>,
}

/// Lex `src` into tokens and line comments.  Never fails: unknown
/// bytes become punct tokens, an unterminated literal runs to EOF.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0;
    let mut line = 1;
    let n = chars.len();

    let is_ident_start = |c: char| c.is_alphabetic() || c == '_';
    let is_ident_cont = |c: char| c.is_alphanumeric() || c == '_';

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            out.line_comments.push((line, chars[start..j].iter().collect()));
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw strings: r"..." / r#"..."# (optionally byte: br).
        if c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r') {
            let prefix_len = if c == 'b' { 2 } else { 1 };
            if let Some((content, consumed, newlines)) = try_raw_string(&chars[i + prefix_len..])
            {
                out.toks.push(Tok { kind: TokKind::Str, text: content, line });
                line += newlines;
                i += prefix_len + consumed;
                continue;
            }
        }
        // Byte strings / byte chars: b"..." / b'x'.
        if c == 'b' && i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '\'') {
            i += 1; // fall through to the quote handling below
            continue;
        }
        if c == '"' {
            let (content, consumed, newlines) = quoted(&chars[i..], '"');
            out.toks.push(Tok { kind: TokKind::Str, text: content, line });
            line += newlines;
            i += consumed;
            continue;
        }
        if c == '\'' {
            // Lifetime iff an identifier follows and the char after it
            // is not a closing quote ('a' is a char, 'a a lifetime).
            let mut j = i + 1;
            if j < n && is_ident_start(chars[j]) {
                let mut k = j;
                while k < n && is_ident_cont(chars[k]) {
                    k += 1;
                }
                if !(k < n && chars[k] == '\'') {
                    out.toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: chars[j..k].iter().collect(),
                        line,
                    });
                    i = k;
                    continue;
                }
            }
            // Char literal (possibly escaped).
            if j < n && chars[j] == '\\' {
                j += 2; // skip the escape lead; scan to the close below
            }
            while j < n && chars[j] != '\'' {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: chars[i + 1..j.min(n)].iter().collect(),
                line,
            });
            i = (j + 1).min(n);
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_ident_cont(chars[j]) || chars[j] == '.') {
                // Stop at a range operator (`0..x`) or method call on a
                // literal — a '.' not followed by a digit ends the token.
                if chars[j] == '.' && !(j + 1 < n && chars[j + 1].is_ascii_digit()) {
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Num, text: chars[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(chars[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: chars[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// Attempt to lex a raw string starting at `rest` (positioned just
/// after the `r` / `br` prefix).  Returns `(contents, chars consumed
/// after the prefix, newlines inside)`.
fn try_raw_string(rest: &[char]) -> Option<(String, usize, usize)> {
    let mut hashes = 0;
    while hashes < rest.len() && rest[hashes] == '#' {
        hashes += 1;
    }
    if hashes >= rest.len() || rest[hashes] != '"' {
        return None;
    }
    let body_start = hashes + 1;
    let mut j = body_start;
    while j < rest.len() {
        if rest[j] == '"'
            && rest[j + 1..].iter().take(hashes).filter(|&&c| c == '#').count() == hashes
        {
            let content: String = rest[body_start..j].iter().collect();
            let newlines = content.matches('\n').count();
            return Some((content, j + 1 + hashes, newlines));
        }
        j += 1;
    }
    let content: String = rest[body_start..].iter().collect();
    let newlines = content.matches('\n').count();
    Some((content, rest.len(), newlines))
}

/// Lex a quoted literal with backslash escapes, starting at the
/// opening quote.  Returns `(contents, chars consumed, newlines)`.
fn quoted(rest: &[char], quote: char) -> (String, usize, usize) {
    let mut j = 1;
    let mut content = String::new();
    let mut newlines = 0;
    while j < rest.len() {
        match rest[j] {
            '\\' if j + 1 < rest.len() => {
                content.push(rest[j]);
                content.push(rest[j + 1]);
                if rest[j + 1] == '\n' {
                    newlines += 1;
                }
                j += 2;
            }
            c if c == quote => return (content, j + 1, newlines),
            c => {
                if c == '\n' {
                    newlines += 1;
                }
                content.push(c);
                j += 1;
            }
        }
    }
    (content, rest.len(), newlines)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_are_skipped_and_captured() {
        let l = lex("let x = 1; // detlint: allow(D1) -- ok\n/* skip /* nested */ me */ y");
        assert_eq!(l.line_comments.len(), 1);
        assert!(l.line_comments[0].1.contains("allow(D1)"));
        assert_eq!(idents("let x = 1; // HashMap\n/* HashMap */ y"), ["let", "x", "y"]);
    }

    #[test]
    fn strings_emit_contents_not_code() {
        let l = lex(r#"let s = "HashMap.iter()"; call(s);"#);
        let strs: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, ["HashMap.iter()"]);
        // The string contents never appear as idents.
        assert!(!idents(r#"let s = "HashMap";"#).contains(&"HashMap".to_string()));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let l = lex(r##"let s = r#"a "quoted" name"#; x"##);
        let strs: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, [r#"a "quoted" name"#]);
        assert!(l.toks.iter().any(|t| t.is_ident("x")));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifetimes, ["a", "a"]);
        let chars: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Char).map(|t| t.text.clone()).collect();
        assert_eq!(chars, ["x", "\\n"]);
    }

    #[test]
    fn lines_are_tracked_through_literals() {
        let l = lex("a\nb \"two\nlines\" c\nd");
        let find = |name: &str| l.toks.iter().find(|t| t.is_ident(name)).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 2);
        assert_eq!(find("c"), 3);
        assert_eq!(find("d"), 4);
    }

    #[test]
    fn double_colon_is_two_colon_puncts() {
        let l = lex("std::time::Instant::now()");
        let colons = l.toks.iter().filter(|t| t.is_punct(':')).count();
        assert_eq!(colons, 6);
        assert_eq!(idents("std::time::Instant::now()"), ["std", "time", "Instant", "now"]);
    }

    #[test]
    fn numeric_literals_do_not_absorb_methods_or_ranges() {
        // `1.0f64` is one Num token (suffix included); the method name
        // after the second dot must still surface as an ident.
        assert_eq!(idents("1.0f64.total_cmp(&x); 0..10"), ["total_cmp", "x"]);
        let l = lex("1.0.partial_cmp(&x)");
        assert!(l.toks.iter().any(|t| t.is_ident("partial_cmp")));
    }
}
