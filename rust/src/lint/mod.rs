//! `detlint` — the repo-native determinism & invariant lint.
//!
//! Everything this repo claims — golden-seed checksums, macro-vs-micro
//! bit-identity, TP fingerprint equivalences — rests on the simulator
//! being a pure function of its seed.  This module enforces that
//! contract statically, over the token stream of `src/` (lexed by the
//! dependency-free [`lexer`] — no `syn`, the container is offline):
//!
//! * **D1** — no `HashMap`/`HashSet` *iteration* in simulator scope.
//!   Keyed lookup (`get`/`insert`/`entry`/..) is deterministic and
//!   fine; `iter`/`keys`/`values`/`drain`/`retain`/`for .. in map`
//!   visit entries in hash order and are flagged.  Migrate to
//!   `BTreeMap`/sorted order, or annotate with the bit-identity
//!   argument (e.g. an order-insensitive integer sum).
//! * **D2** — no `.partial_cmp(..)` call sites in simulator scope;
//!   use the NaN-total `f64::total_cmp`.  `fn partial_cmp`
//!   *definitions* that delegate to a total `cmp` are not flagged.
//! * **D3** — no `Instant::now` / `SystemTime` / `thread_rng` /
//!   `from_entropy` anywhere in `src/` except `main.rs`, `bin/`, and
//!   the pjrt-gated `server/` — wall-clock and ambient entropy must
//!   never leak into simulated time.
//! * **D4** — every scheduler name in the `PolicySpec` registry
//!   (`cluster/policy.rs`, `fn names`) *and* every predictor name in
//!   the `predict::names()` registry (`predict.rs`) must appear in the
//!   coverage lists of both `tests/golden_seed.rs` and
//!   `tests/macro_equivalence.rs`, so a new policy or predictor cannot
//!   ship with its seeded behavior unpinned.  The churn-event registry
//!   (`ChurnSpec::names()`, `cluster/elastic.rs`) is cross-referenced
//!   the same way against `tests/elastic.rs`, so a new fault kind
//!   cannot ship without an elastic-suite determinism pin.
//!
//! Simulator scope is `cluster/`, `coordinator/`, `sim/`, `engine/`,
//! plus `fleet.rs`, `kernelmodel.rs`, `workload.rs`, `metrics.rs`,
//! `predict.rs`.
//!
//! ## Suppression grammar
//!
//! A finding is suppressed only by a justified annotation **on the
//! offending line**:
//!
//! ```text
//! // detlint: allow(D1) -- u64 sum over values; order-insensitive
//! ```
//!
//! The reason after `--` is mandatory; a malformed or reason-less
//! annotation is itself a finding (`allow`).  `detlint --list-allows`
//! prints the full audit trail and **exits nonzero when any annotation
//! is `STALE`** (no longer suppresses anything), so dead allows cannot
//! linger unaudited.  The regular run reports stale allows as warnings
//! only, so a detector refinement cannot brick CI.
//!
//! ## Honest limits
//!
//! The detectors are lexical, not semantic: D1 only tracks names
//! declared as hash containers *in the same file* (field, annotated
//! `let`, or `let .. = HashMap::new()`), so a map smuggled through a
//! type alias or returned by reference escapes it.  That bounds
//! false positives (a `Vec` named like a map never fires) at the cost
//! of known blind spots; the golden-seed suite remains the dynamic
//! backstop.

pub mod lexer;
pub mod rules;

use lexer::lex;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Lint rule identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Hash-order iteration in sim scope.
    D1,
    /// NaN-unsafe `partial_cmp` in sim scope.
    D2,
    /// Wall-clock / ambient entropy on the simulation path.
    D3,
    /// Registry scheduler missing from coverage tests.
    D4,
    /// Malformed `// detlint: allow(..)` annotation.
    BadAllow,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::D4 => "D4",
            Rule::BadAllow => "allow",
        }
    }

    fn parse(s: &str) -> Option<Rule> {
        match s {
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "D4" => Some(Rule::D4),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One unsuppressed diagnostic.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// A parsed `// detlint: allow(<rule>) -- <reason>` annotation.
#[derive(Debug, Clone)]
pub struct AllowRecord {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
    /// Did this annotation suppress a finding in this run?  `false`
    /// means the annotation is stale (reported as a warning).
    pub used: bool,
}

/// Lint result for one source file.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowRecord>,
}

/// Lint result for the whole crate.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowRecord>,
}

/// Strip a leading `src/` so callers can pass either crate-relative
/// (`src/cluster/mod.rs`) or scope-relative (`cluster/mod.rs`) paths.
fn scope_rel(rel: &str) -> &str {
    rel.strip_prefix("src/").unwrap_or(rel)
}

/// Is `rel` (relative to `src/`) inside simulator scope (D1/D2)?
pub fn sim_scoped(rel: &str) -> bool {
    let rel = scope_rel(rel);
    ["cluster/", "coordinator/", "sim/", "engine/"].iter().any(|p| rel.starts_with(p))
        || matches!(
            rel,
            "fleet.rs" | "kernelmodel.rs" | "workload.rs" | "metrics.rs" | "predict.rs"
        )
}

/// May `rel` touch the wall clock / ambient entropy (D3 exempt)?
pub fn wallclock_allowed(rel: &str) -> bool {
    let rel = scope_rel(rel);
    rel == "main.rs" || rel.starts_with("server/") || rel.starts_with("bin/")
}

/// Parse one line comment.  `None`: not a detlint annotation.
/// `Some(Ok(..))`: well-formed allow.  `Some(Err(msg))`: malformed.
fn parse_allow(text: &str) -> Option<Result<(Rule, String), String>> {
    let idx = text.find("detlint:")?;
    let rest = text[idx + "detlint:".len()..].trim_start();
    let Some(r) = rest.strip_prefix("allow(") else {
        return Some(Err("expected `allow(<rule>)` after `detlint:`".to_string()));
    };
    let Some(close) = r.find(')') else {
        return Some(Err("unclosed `allow(`".to_string()));
    };
    let rule_s = r[..close].trim();
    let Some(rule) = Rule::parse(rule_s) else {
        return Some(Err(format!("unknown rule `{rule_s}` (expected D1..D4)")));
    };
    let after = r[close + 1..].trim_start();
    let Some(reason) = after.strip_prefix("--") else {
        return Some(Err(format!(
            "allow({rule}) without a justification; write `allow({rule}) -- <reason>`"
        )));
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Some(Err(format!(
            "allow({rule}) with an empty justification; write `allow({rule}) -- <reason>`"
        )));
    }
    Some(Ok((rule, reason.to_string())))
}

/// Lint a single source file.  `rel` is the path relative to `src/`
/// (a leading `src/` is tolerated); it selects which rules apply and
/// becomes the `file` field of the produced findings/allows.
pub fn check_source(rel: &str, src: &str) -> FileReport {
    let lexed = lex(src);
    let mut raw: Vec<(usize, Rule, String)> = Vec::new();
    if sim_scoped(rel) {
        for (line, msg) in rules::d1_hash_iteration(&lexed) {
            raw.push((line, Rule::D1, msg));
        }
        for (line, msg) in rules::d2_partial_cmp(&lexed) {
            raw.push((line, Rule::D2, msg));
        }
    }
    if !wallclock_allowed(rel) {
        for (line, msg) in rules::d3_wall_clock(&lexed) {
            raw.push((line, Rule::D3, msg));
        }
    }
    raw.sort_by_key(|r| (r.0, r.1.id()));

    let mut report = FileReport::default();
    for (line, comment) in &lexed.line_comments {
        match parse_allow(comment) {
            None => {}
            Some(Ok((rule, reason))) => report.allows.push(AllowRecord {
                file: rel.to_string(),
                line: *line,
                rule,
                reason,
                used: false,
            }),
            Some(Err(msg)) => report.findings.push(Finding {
                file: rel.to_string(),
                line: *line,
                rule: Rule::BadAllow,
                message: msg,
            }),
        }
    }
    for (line, rule, message) in raw {
        let allow = report
            .allows
            .iter_mut()
            .find(|a| a.line == line && a.rule == rule);
        if let Some(a) = allow {
            a.used = true;
        } else {
            report.findings.push(Finding { file: rel.to_string(), line, rule, message });
        }
    }
    report.findings.sort_by_key(|f| (f.line, f.rule.id()));
    report
}

/// D4 as a pure function, exposed for fixture tests: cross-reference
/// the registry literals in `policy_src` against each `(path, source)`
/// coverage file.  Returns *unsuppressed* findings anchored at
/// `policy_path`; [`check_crate`] applies allow suppression on top.
pub fn check_registry_coverage(
    policy_path: &str,
    policy_src: &str,
    coverage: &[(&str, &str)],
) -> Vec<Finding> {
    let policy = lex(policy_src);
    let names = rules::registry_names(&policy);
    let lexed: Vec<(&str, lexer::Lexed)> = coverage.iter().map(|(p, s)| (*p, lex(s))).collect();
    let refs: Vec<(&str, &lexer::Lexed)> = lexed.iter().map(|(p, l)| (*p, l)).collect();
    rules::d4_registry_coverage(&names, policy_path, &refs)
        .into_iter()
        .map(|(file, line, message)| Finding { file, line, rule: Rule::D4, message })
        .collect()
}

/// Deterministic (sorted) recursive walk collecting `.rs` files.
fn walk_sorted(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_sorted(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the whole crate rooted at `rust_root` (the directory holding
/// `src/` and `tests/`): D1–D3 over every file under `src/`, then the
/// D4 registry cross-reference.  File paths in the report are
/// `rust_root`-relative (`src/cluster/mod.rs`, ..).
pub fn check_crate(rust_root: &Path) -> io::Result<LintReport> {
    let src_dir = rust_root.join("src");
    let mut files = Vec::new();
    walk_sorted(&src_dir, &mut files)?;

    let mut report = LintReport::default();
    for path in &files {
        let rel = path
            .strip_prefix(&src_dir)
            .expect("walked file under src/")
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)?;
        let mut file_report = check_source(&rel, &src);
        let display = format!("src/{rel}");
        for f in &mut file_report.findings {
            f.file = display.clone();
        }
        for a in &mut file_report.allows {
            a.file = display.clone();
        }
        report.findings.extend(file_report.findings);
        report.allows.extend(file_report.allows);
    }

    // D4: registry names vs. coverage test files.
    const POLICY: &str = "src/cluster/policy.rs";
    const COVERAGE: [&str; 2] = ["tests/golden_seed.rs", "tests/macro_equivalence.rs"];
    let policy_src = fs::read_to_string(rust_root.join(POLICY))?;
    let mut coverage_srcs = Vec::new();
    for t in COVERAGE {
        match fs::read_to_string(rust_root.join(t)) {
            Ok(s) => coverage_srcs.push((t, s)),
            Err(_) => report.findings.push(Finding {
                file: t.to_string(),
                line: 1,
                rule: Rule::D4,
                message: format!(
                    "coverage test file {t} is missing; the registry cross-reference \
                     cannot hold without it"
                ),
            }),
        }
    }
    let coverage: Vec<(&str, &str)> =
        coverage_srcs.iter().map(|(p, s)| (*p, s.as_str())).collect();
    for f in check_registry_coverage(POLICY, &policy_src, &coverage) {
        let allow = report
            .allows
            .iter_mut()
            .find(|a| a.file == POLICY && a.line == f.line && a.rule == Rule::D4);
        if let Some(a) = allow {
            a.used = true;
        } else {
            report.findings.push(f);
        }
    }
    // D4 again for the length-predictor registry (`predict::names()`),
    // against the same coverage files.
    const PREDICT: &str = "src/predict.rs";
    let predict_src = fs::read_to_string(rust_root.join(PREDICT))?;
    for f in check_registry_coverage(PREDICT, &predict_src, &coverage) {
        let allow = report
            .allows
            .iter_mut()
            .find(|a| a.file == PREDICT && a.line == f.line && a.rule == Rule::D4);
        if let Some(a) = allow {
            a.used = true;
        } else {
            report.findings.push(f);
        }
    }
    // D4 once more for the churn-event registry (`ChurnSpec::names()`)
    // against the elastic fault-injection suite: a new fault kind must
    // carry a determinism pin before it can ship.
    const ELASTIC: &str = "src/cluster/elastic.rs";
    const ELASTIC_COVERAGE: &str = "tests/elastic.rs";
    let elastic_src = fs::read_to_string(rust_root.join(ELASTIC))?;
    match fs::read_to_string(rust_root.join(ELASTIC_COVERAGE)) {
        Err(_) => report.findings.push(Finding {
            file: ELASTIC_COVERAGE.to_string(),
            line: 1,
            rule: Rule::D4,
            message: format!(
                "coverage test file {ELASTIC_COVERAGE} is missing; the churn-event \
                 cross-reference cannot hold without it"
            ),
        }),
        Ok(elastic_cov) => {
            for f in check_registry_coverage(
                ELASTIC,
                &elastic_src,
                &[(ELASTIC_COVERAGE, &elastic_cov)],
            ) {
                let allow = report
                    .allows
                    .iter_mut()
                    .find(|a| a.file == ELASTIC && a.line == f.line && a.rule == Rule::D4);
                if let Some(a) = allow {
                    a.used = true;
                } else {
                    report.findings.push(f);
                }
            }
        }
    }
    report.findings.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then_with(|| a.rule.id().cmp(b.rule.id()))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_classification() {
        assert!(sim_scoped("cluster/mod.rs"));
        assert!(sim_scoped("src/coordinator/migrate.rs"));
        assert!(sim_scoped("metrics.rs"));
        assert!(sim_scoped("predict.rs"));
        assert!(!sim_scoped("cli.rs"));
        assert!(!sim_scoped("lint/mod.rs"));
        assert!(wallclock_allowed("main.rs"));
        assert!(wallclock_allowed("bin/detlint.rs"));
        assert!(wallclock_allowed("server/pjrt.rs"));
        assert!(!wallclock_allowed("cluster/mod.rs"));
    }

    #[test]
    fn allow_suppresses_on_same_line_only() {
        let src = "struct S { m: HashMap<u64, u64> }\n\
                   impl S {\n\
                   fn a(&self) -> u64 { self.m.values().sum() } // detlint: allow(D1) -- u64 sum, order-insensitive\n\
                   fn b(&self) -> u64 { self.m.values().count() as u64 }\n\
                   }\n";
        let rep = check_source("cluster/x.rs", src);
        assert_eq!(rep.findings.len(), 1, "{:?}", rep.findings);
        assert_eq!(rep.findings[0].line, 4);
        assert_eq!(rep.findings[0].rule, Rule::D1);
        assert_eq!(rep.allows.len(), 1);
        assert!(rep.allows[0].used);
    }

    #[test]
    fn allow_with_wrong_rule_does_not_suppress() {
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); } // detlint: allow(D1) -- wrong rule\n";
        let rep = check_source("sim/x.rs", src);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, Rule::D2);
        assert!(!rep.allows[0].used, "mismatched allow must stay stale");
    }

    #[test]
    fn malformed_allows_are_findings() {
        for bad in [
            "fn f() {} // detlint: allow(D1)\n",
            "fn f() {} // detlint: allow(D1) --   \n",
            "fn f() {} // detlint: allow(D9) -- nope\n",
            "fn f() {} // detlint: disable(D1) -- nope\n",
        ] {
            let rep = check_source("cluster/x.rs", bad);
            assert_eq!(rep.findings.len(), 1, "{bad:?} -> {:?}", rep.findings);
            assert_eq!(rep.findings[0].rule, Rule::BadAllow);
        }
    }

    #[test]
    fn rules_scope_by_path() {
        let d1 = "struct S { m: HashMap<u64, u64> }\n\
                  impl S { fn f(&self) -> u64 { self.m.values().sum() } }\n";
        assert!(check_source("cli.rs", d1).findings.is_empty(), "D1 only fires in sim scope");
        assert!(!check_source("engine/x.rs", d1).findings.is_empty());
        let d3 = "fn f() { let t = std::time::Instant::now(); }\n";
        assert!(check_source("main.rs", d3).findings.is_empty(), "main.rs may read the clock");
        assert!(!check_source("workload.rs", d3).findings.is_empty());
        assert!(!check_source("cli.rs", d3).findings.is_empty(), "D3 covers non-sim library code");
    }

    #[test]
    fn registry_coverage_cross_reference() {
        let policy = "impl PolicySpec { pub fn names() -> &'static [&'static str] { \
                      &[\"cascade\", \"vllm\"] } }";
        let both = "const REGISTRY_COVERAGE: [&str; 2] = [\"cascade\", \"vllm\"];";
        let one = "const REGISTRY_COVERAGE: [&str; 1] = [\"cascade\"];";
        assert!(check_registry_coverage("p.rs", policy, &[("a.rs", both), ("b.rs", both)])
            .is_empty());
        let f = check_registry_coverage("p.rs", policy, &[("a.rs", both), ("b.rs", one)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::D4);
        assert!(f[0].message.contains("vllm") && f[0].message.contains("b.rs"));
    }
}
