//! The determinism rules D1–D4.
//!
//! Each rule is a pure function over the lexed token stream (plus, for
//! D4, the registry/test cross-reference inputs) returning raw
//! findings — `(line, message)` pairs.  Suppression via
//! `// detlint: allow(..)` annotations happens one layer up, in
//! [`crate::lint::check_source`], so the rules stay trivially
//! testable.
//!
//! The detectors are deliberately lexical, not semantic — see the
//! module docs of [`crate::lint`] for the exact approximations and
//! their known blind spots.

use super::lexer::{Lexed, Tok, TokKind};

/// Map/set methods whose results depend on hash iteration order.
/// Keyed probes (`get`, `insert`, `remove`, `contains_key`, `entry`,
/// `len`, `is_empty`) are deterministic and deliberately absent.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Identifiers in `toks` declared with a `HashMap`/`HashSet` type.
///
/// Two declaration shapes are recognised (they cover every struct
/// field, annotated `let`, and function parameter in this crate):
///
/// * `name : [path::]HashMap<` / `HashSet<` — type-annotated binding;
/// * `let [mut] name = [path::]HashMap::new()` (or `::default()` /
///   `::with_capacity(..)` / `::from(..)`) — inferred binding.
fn hash_container_names(toks: &[Tok]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if !(t.is_ident("HashMap") || t.is_ident("HashSet")) {
            continue;
        }
        // Walk backward over the `::`-separated path to its start.
        let mut j = i;
        while j >= 3
            && toks[j - 1].is_punct(':')
            && toks[j - 2].is_punct(':')
            && toks[j - 3].kind == TokKind::Ident
        {
            j -= 3;
        }
        // Shape 1: `name : path HashMap <`.
        if i + 1 < toks.len()
            && toks[i + 1].is_punct('<')
            && j >= 2
            && toks[j - 1].is_punct(':')
            && !toks[j - 2].is_punct(':')
            && toks[j - 2].kind == TokKind::Ident
        {
            push_unique(&mut names, &toks[j - 2].text);
            continue;
        }
        // Shape 2: `let [mut] name = path HashMap :: ctor`.
        let is_ctor_call = toks[i + 1..]
            .iter()
            .take(3)
            .enumerate()
            .all(|(k, t)| match k {
                0 | 1 => t.is_punct(':'),
                _ => {
                    t.kind == TokKind::Ident
                        && matches!(t.text.as_str(), "new" | "default" | "with_capacity" | "from")
                }
            });
        if is_ctor_call && j >= 2 && toks[j - 1].is_punct('=') && toks[j - 2].kind == TokKind::Ident
        {
            let name = &toks[j - 2].text;
            let let_pos = j.checked_sub(3).map(|k| &toks[k]);
            let is_let = matches!(let_pos, Some(t) if t.is_ident("let") || t.is_ident("mut"));
            if is_let {
                push_unique(&mut names, name);
            }
        }
    }
    names
}

fn push_unique(names: &mut Vec<String>, name: &str) {
    if !names.iter().any(|n| n == name) {
        names.push(name.to_string());
    }
}

/// D1: iteration over a `HashMap`/`HashSet` in simulator scope.
///
/// Flags `name.iter()`-style calls (any of [`ITER_METHODS`]) and
/// `for .. in [&[mut]] [self.]name` loops where `name` was declared as
/// a hash container in the same file.  Keyed lookups never fire.
pub fn d1_hash_iteration(lexed: &Lexed) -> Vec<(usize, String)> {
    let toks = &lexed.toks;
    let names = hash_container_names(toks);
    if names.is_empty() {
        return Vec::new();
    }
    let known = |t: &Tok| t.kind == TokKind::Ident && names.iter().any(|n| *n == t.text);
    let mut findings = Vec::new();
    for i in 0..toks.len() {
        // `name . method (`
        if i + 2 < toks.len()
            && toks[i].is_punct('.')
            && i >= 1
            && known(&toks[i - 1])
            && toks[i + 1].kind == TokKind::Ident
            && ITER_METHODS.iter().any(|m| toks[i + 1].text == *m)
            && toks[i + 2].is_punct('(')
        {
            findings.push((
                toks[i + 1].line,
                format!(
                    "hash-order iteration: `{}.{}()` on a HashMap/HashSet in sim scope; \
                     use BTreeMap/sorted order or justify with an allow annotation",
                    toks[i - 1].text, toks[i + 1].text
                ),
            ));
        }
        // `for <pat> in <expr> {` where <expr> reduces to a known name.
        if toks[i].is_ident("for") {
            let Some(in_pos) = toks[i + 1..]
                .iter()
                .take(24)
                .position(|t| t.is_ident("in"))
                .map(|p| i + 1 + p)
            else {
                continue;
            };
            let Some(body_pos) = toks[in_pos + 1..]
                .iter()
                .take(12)
                .position(|t| t.is_punct('{'))
                .map(|p| in_pos + 1 + p)
            else {
                continue;
            };
            let expr: Vec<&Tok> = toks[in_pos + 1..body_pos]
                .iter()
                .filter(|t| {
                    !(t.is_punct('&')
                        || t.is_punct('(')
                        || t.is_punct(')')
                        || t.is_punct('.')
                        || t.is_ident("mut")
                        || t.is_ident("self"))
                })
                .collect();
            if let [only] = expr.as_slice() {
                if known(only) {
                    findings.push((
                        only.line,
                        format!(
                            "hash-order iteration: `for .. in {}` over a HashMap/HashSet \
                             in sim scope; use BTreeMap/sorted order or justify with an \
                             allow annotation",
                            only.text
                        ),
                    ));
                }
            }
        }
    }
    findings
}

/// D2: `.partial_cmp(..)` call sites in simulator scope.
///
/// Sim-scope float orderings must be NaN-safe (`f64::total_cmp`): a
/// single NaN under `partial_cmp` silently degrades to `Equal` (or
/// panics through `unwrap`), and the resulting ordering depends on the
/// comparison sequence.  Trait *definitions* (`fn partial_cmp`) that
/// delegate to a total `cmp` are idiomatic and not flagged — the
/// pattern requires a preceding `.`, i.e. an actual call.
pub fn d2_partial_cmp(lexed: &Lexed) -> Vec<(usize, String)> {
    let toks = &lexed.toks;
    let mut findings = Vec::new();
    for i in 1..toks.len() {
        if toks[i].is_ident("partial_cmp")
            && toks[i - 1].is_punct('.')
            && i + 1 < toks.len()
            && toks[i + 1].is_punct('(')
        {
            findings.push((
                toks[i].line,
                "NaN-unsafe float ordering: `.partial_cmp(..)` in sim scope; \
                 use `f64::total_cmp` (or justify with an allow annotation)"
                    .to_string(),
            ));
        }
    }
    findings
}

/// D3: wall-clock / ambient-entropy access on the simulation path.
///
/// Simulated time flows from the event queue and randomness from the
/// seeded [`crate::sim::Rng`]; `Instant::now`, `SystemTime`,
/// `thread_rng`, and `from_entropy` all smuggle host state into what
/// must be a pure function of the seed.
pub fn d3_wall_clock(lexed: &Lexed) -> Vec<(usize, String)> {
    let toks = &lexed.toks;
    let mut findings = Vec::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let hit = match t.text.as_str() {
            "Instant" => {
                i + 3 < toks.len()
                    && toks[i + 1].is_punct(':')
                    && toks[i + 2].is_punct(':')
                    && toks[i + 3].is_ident("now")
            }
            "SystemTime" | "thread_rng" | "from_entropy" => true,
            _ => false,
        };
        if hit {
            findings.push((
                t.line,
                format!(
                    "wall-clock/entropy access: `{}` outside main.rs/bin//server/; \
                     simulation paths must be pure functions of the seed \
                     (or justify with an allow annotation)",
                    t.text
                ),
            ));
        }
    }
    findings
}

/// Extract the registry names from `PolicySpec::names()`: the string
/// literals of the array between the first `[`/`]` pair after
/// `fn names`.  Returns `(name, line-of-literal)` pairs; empty when
/// the function is not found (the caller reports that as a finding).
pub fn registry_names(policy: &Lexed) -> Vec<(String, usize)> {
    let toks = &policy.toks;
    let Some(fn_pos) = toks
        .windows(2)
        .position(|w| w[0].is_ident("fn") && w[1].is_ident("names"))
    else {
        return Vec::new();
    };
    // Skip past the signature (whose return type contains a `[`) to
    // the body, then take the first array literal.
    let Some(body) = toks[fn_pos..].iter().position(|t| t.is_punct('{')).map(|p| fn_pos + p)
    else {
        return Vec::new();
    };
    let Some(open) = toks[body..].iter().position(|t| t.is_punct('[')).map(|p| body + p) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for t in &toks[open + 1..] {
        if t.is_punct(']') {
            break;
        }
        if t.kind == TokKind::Str {
            out.push((t.text.clone(), t.line));
        }
    }
    out
}

/// D4: every registry scheduler name must appear as a string literal
/// in each listed coverage test file, so a newly registered policy
/// cannot ship without a pinned golden-seed / macro-equivalence entry.
pub fn d4_registry_coverage(
    names: &[(String, usize)],
    policy_path: &str,
    coverage: &[(&str, &Lexed)],
) -> Vec<(String, usize, String)> {
    let mut findings = Vec::new();
    if names.is_empty() {
        findings.push((
            policy_path.to_string(),
            1,
            "registry cross-reference: could not locate string literals in \
             `PolicySpec::names()` — the D4 anchor moved; update the lint"
                .to_string(),
        ));
        return findings;
    }
    for (name, line) in names {
        for (test_path, lexed) in coverage {
            let present = lexed
                .toks
                .iter()
                .any(|t| t.kind == TokKind::Str && t.text == *name);
            if !present {
                findings.push((
                    policy_path.to_string(),
                    *line,
                    format!(
                        "registry scheduler `{name}` is missing from the coverage list \
                         in {test_path}; add it so the scheduler's seeded behavior is pinned"
                    ),
                ));
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    #[test]
    fn d1_flags_iteration_not_lookup() {
        let src = "
            struct S { m: std::collections::HashMap<u64, u64>, v: Vec<u64> }
            impl S {
                fn bad(&self) -> u64 { self.m.values().sum() }
                fn also_bad(&mut self) { for (k, v) in &self.m { self.use_(k, v); } }
                fn fine(&self) -> Option<&u64> { self.m.get(&1) }
                fn vec_ok(&self) -> u64 { self.v.iter().sum() }
            }";
        let f = d1_hash_iteration(&lex(src));
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f[0].1.contains("m.values()"));
        assert!(f[1].1.contains("for .. in m"));
    }

    #[test]
    fn d1_sees_let_bindings_and_hashset() {
        let src = "
            fn f() {
                let mut seen = HashSet::new();
                seen.insert(1);
                for x in seen.iter() { use_(x); }
            }";
        let f = d1_hash_iteration(&lex(src));
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn d1_ignores_strings_and_comments() {
        let src = "// self.m.values() in a comment\nfn f() -> &'static str { \"m.iter()\" }";
        assert!(d1_hash_iteration(&lex(src)).is_empty());
    }

    #[test]
    fn d2_flags_calls_not_definitions() {
        let good = "impl PartialOrd for X { fn partial_cmp(&self, o: &X) -> Option<Ordering> \
                    { Some(self.cmp(o)) } }";
        assert!(d2_partial_cmp(&lex(good)).is_empty());
        let bad = "fn f(a: f64, b: f64) { a.partial_cmp(&b).unwrap(); }";
        assert_eq!(d2_partial_cmp(&lex(bad)).len(), 1);
    }

    #[test]
    fn d3_flags_wall_clock_tokens() {
        let src = "let t = std::time::Instant::now(); let s = SystemTime::now();";
        assert_eq!(d3_wall_clock(&lex(src)).len(), 2);
        // `Instant` as a plain type (no ::now) passes — storing one is
        // not the same as reading the clock.
        assert!(d3_wall_clock(&lex("fn f(t: Instant) {}")).is_empty());
    }

    #[test]
    fn d4_cross_reference() {
        let policy = lex("pub fn names() -> &'static [&'static str] { &[\"a\", \"b\"] }");
        let names = registry_names(&policy);
        assert_eq!(names.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(), ["a", "b"]);
        let has_both = lex("const C: [&str; 2] = [\"a\", \"b\"];");
        let missing_b = lex("const C: [&str; 1] = [\"a\"];");
        assert!(d4_registry_coverage(
            &names,
            "policy.rs",
            &[("t1.rs", &has_both), ("t2.rs", &has_both)]
        )
        .is_empty());
        let f = d4_registry_coverage(
            &names,
            "policy.rs",
            &[("t1.rs", &has_both), ("t2.rs", &missing_b)],
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].2.contains('b') && f[0].2.contains("t2.rs"));
    }
}
