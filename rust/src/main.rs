//! `cascade-infer` — leader entrypoint.
//!
//! Subcommands drive the two halves of the reproduction:
//! * `sim` / `sweep` / `plan` / `fit` / `gen-trace` — the 16-instance
//!   simulated testbed used by every figure, constructed through the
//!   [`cascade_infer::experiment::Experiment`] builder,
//! * `serve` — the real PJRT path over the AOT artifacts.
//!
//! Unknown `--model`, `--gpu`, `--scheduler`, and `--workload` values
//! are hard errors listing the valid choices (exit code 2) — never a
//! silent fallback.

use cascade_infer::cli::{Args, USAGE};
use cascade_infer::cluster::PolicySpec;
use cascade_infer::config::{Config, ExperimentConfig};
use cascade_infer::coordinator::plan::{MigrationCost, Planner};
use cascade_infer::experiment::{self, Experiment, ExperimentBuilder};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::metrics::Slo;
use cascade_infer::qoe;
use cascade_infer::sweep;
use cascade_infer::workload::{self, LengthHistogram, ShareGptLike};

/// Print a CLI-level error and exit 2.
fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

fn gpu_by_name_or_die(name: &str) -> GpuProfile {
    experiment::resolve_gpu(name).unwrap_or_else(|e| die(&e.to_string()))
}

fn model_by_name_or_die(name: &str) -> cascade_infer::models::ModelProfile {
    experiment::resolve_model(name).unwrap_or_else(|e| die(&e.to_string()))
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "sim" => cmd_sim(&args),
        "sweep" => cmd_sweep(&args),
        "plan" => cmd_plan(&args),
        "fit" => cmd_fit(&args),
        "gen-trace" => cmd_gen_trace(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => {
            eprintln!("error: unknown subcommand `{other}`\n");
            println!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Shared `sim`/`sweep` construction: config-file defaults, then
/// explicit CLI flags on top.
fn builder_from_args(args: &Args) -> ExperimentBuilder {
    let file_cfg = match args.get("config") {
        Some(path) => match Config::load(path) {
            Ok(cfg) => ExperimentConfig::from_config(&cfg),
            // `Config::load` surfaces `ParseError` with its line
            // number; IO errors carry the path context here.
            Err(e) => die(&format!("cannot load config `{path}`: {e}")),
        },
        None => ExperimentConfig::default(),
    };
    let mut b = Experiment::from_config(&file_cfg);
    if let Some(m) = args.get("model") {
        b = b.model(m);
    }
    if let Some(g) = args.get("gpu") {
        b = b.gpu(g);
    }
    if let Some(n) = args.get("instances") {
        b = b.instances(n.parse().unwrap_or_else(|_| die("--instances must be an integer")));
    }
    if let Some(r) = args.get("rate") {
        b = b.rate(r.parse().unwrap_or_else(|_| die("--rate must be a number")));
    }
    if let Some(n) = args.get("requests") {
        b = b.requests(n.parse().unwrap_or_else(|_| die("--requests must be an integer")));
    }
    if let Some(s) = args.get("seed") {
        b = b.seed(s.parse().unwrap_or_else(|_| die("--seed must be an integer")));
    }
    if let Some(s) = args.get("scheduler") {
        b = b.scheduler(s);
    }
    if let Some(w) = args.get("workload") {
        b = b.workload_name(w);
    }
    if let Some(f) = args.get("fleet") {
        b = b.fleet(f);
    }
    if let Some(p) = args.get("predictor") {
        b = b.predictor(p);
    }
    if let Some(l) = args.get("layout") {
        b = b.layout(l);
    }
    if let Some(c) = args.get("churn") {
        b = b.churn(c);
    }
    if args.has_flag("micro-step") {
        b = b.micro_step(true);
    }
    b
}

fn cmd_sim(args: &Args) {
    // `--stream` pulls arrivals lazily from the workload stream
    // (O(instances + in-flight) resident memory; bit-identical report);
    // the default materializes the full trace up front.
    let streaming = args.has_flag("stream");
    let (report, stats, has_fleet, predictor) = if streaming {
        let exp = match builder_from_args(args).build_streaming() {
            Ok(e) => e,
            Err(e) => die(&e.to_string()),
        };
        print_sim_header(&exp.cfg, exp.total_requests(), " (streaming)");
        let has_fleet = exp.cfg.fleet.is_some();
        let predictor = exp.cfg.policy.predictor;
        let t0 = std::time::Instant::now();
        let (report, stats) = match exp.run() {
            Ok(r) => r,
            Err(e) => die(&e.to_string()),
        };
        println!("wall time        {:.2}s", t0.elapsed().as_secs_f64());
        (report, stats, has_fleet, predictor)
    } else {
        let exp = match builder_from_args(args).build() {
            Ok(e) => e,
            Err(e) => die(&e.to_string()),
        };
        print_sim_header(&exp.cfg, exp.requests.len(), "");
        let has_fleet = exp.cfg.fleet.is_some();
        let predictor = exp.cfg.policy.predictor;
        let t0 = std::time::Instant::now();
        let (report, stats) = exp.run();
        println!("wall time        {:.2}s", t0.elapsed().as_secs_f64());
        (report, stats, has_fleet, predictor)
    };
    print_sim_metrics(&report, &stats, has_fleet, predictor, streaming);
}

fn print_sim_header(cfg: &cascade_infer::cluster::ClusterConfig, n_requests: usize, tag: &str) {
    let hardware = match &cfg.fleet {
        Some(f) => format!("fleet {f}"),
        None => cfg.gpu.name.to_string(),
    };
    println!(
        "sim: {} x{} on {}, {} requests, scheduler {}{}",
        cfg.model.name, cfg.n_instances, hardware, n_requests, cfg.policy.name, tag
    );
}

fn print_sim_metrics(
    report: &cascade_infer::metrics::Report,
    stats: &cascade_infer::cluster::RunStats,
    has_fleet: bool,
    predictor: cascade_infer::predict::PredictorSpec,
    streaming: bool,
) {
    println!("completed        {}", report.records.len());
    println!(
        "mean TTFT        {:.4}s   p50 {:.4}s  p95 {:.4}s  p99 {:.4}s",
        report.mean_ttft(),
        report.p50_ttft(),
        report.p95_ttft(),
        report.p99_ttft()
    );
    println!("mean TPOT        {:.5}s   p95 {:.5}s", report.mean_tpot(), report.p95_tpot());
    println!("norm latency     {:.5}s/token", report.mean_normalized_latency());
    println!("throughput       {:.1} tok/s", report.throughput_tokens_per_s());
    let slo = Slo { ttft: 1.0, tpot: 0.1 };
    println!("SLO(1s,100ms)    {:.1}%", 100.0 * report.slo_attainment(slo));
    println!(
        "migrations       {} ({} skipped), preemptions {}",
        stats.migrations, stats.migrations_skipped, stats.preemptions
    );
    if streaming {
        // The O(in-flight) residency claim, measured: peak live
        // requests in the arena, not the trace length.
        println!("peak in-flight   {} (arena high water)", stats.arena_high_water);
    }
    if !predictor.is_oracle() {
        println!("predictor        {}", predictor.name());
        println!(
            "mispredictions   {} (re-routes {}, escalations {})",
            stats.mispredictions, stats.predict_reroutes, stats.predict_escalations
        );
    }
    if stats.admit_reroutes > 0 {
        println!(
            "admit reroutes   {} (preferred target's KV pool could never hold them)",
            stats.admit_reroutes
        );
    }
    // PD disaggregation accounting, shown only under a pd layout.
    if stats.pd_handoffs + stats.pd_local_completions + stats.pd_reallocations > 0 {
        println!(
            "pd handoffs      {} ({} KV tokens moved, {} completed at prefill, \
             {} pool re-allocations)",
            stats.pd_handoffs,
            stats.pd_handoff_tokens,
            stats.pd_local_completions,
            stats.pd_reallocations
        );
    }
    // Elastic-fleet accounting, shown only when churn actually fired.
    if stats.spot_kills + stats.drains_started + stats.joins + stats.autoscale_ticks > 0 {
        println!(
            "churn            {} spot kills, {} drains ({} completed, {} forced), {} joins",
            stats.spot_kills,
            stats.drains_started,
            stats.drains_completed,
            stats.drains_forced,
            stats.joins
        );
        println!(
            "preempted reqs   {} ({} recovered, {} KV tokens lost)",
            stats.preempted_requests, stats.recovered, stats.lost_tokens
        );
        if stats.autoscale_ticks > 0 {
            println!(
                "autoscaler       {} ticks, {} scale-outs, {} scale-ins",
                stats.autoscale_ticks, stats.scale_outs, stats.scale_ins
            );
        }
    }
    if stats.rejected > 0 {
        println!(
            "rejected         {} (final length exceeds the routed instance's KV pool)",
            stats.rejected
        );
        for r in &stats.rejections {
            println!(
                "                 request {} -> instance {}: needs {} tokens, pool {}",
                r.request, r.instance, r.final_len, r.pool_tokens
            );
        }
    }
    println!("stages           {:?}", stats.stages.iter().map(|s| s.len()).collect::<Vec<_>>());
    println!("boundaries       {:?}", stats.final_boundaries);
    // Per-instance report: GPU tag, relative capacity, output-token
    // share.  Printed whenever the fleet is explicit so mixed-fleet
    // balance (does the H100 carry its larger share?) is visible.
    if has_fleet {
        let total: u64 = stats.counters.output_tokens.values().sum::<u64>().max(1);
        println!("per-instance     id  gpu    tp  cap    out-tokens  share");
        for i in 0..stats.instance_gpus.len() {
            let toks = *stats.counters.output_tokens.get(&i).unwrap_or(&0);
            println!(
                "                 {:<3} {:<6} {:<3} {:<6.3} {:>10}  {:>5.1}%",
                i,
                stats.instance_gpus[i],
                stats.instance_tp.get(i).copied().unwrap_or(1),
                stats.instance_capacity[i],
                toks,
                100.0 * toks as f64 / total as f64
            );
        }
    }
}

/// Grid over rates x schedulers sharing one workload per rate; prints
/// a comparison table (the shape of Figs. 6/7/10 from the CLI).
fn cmd_sweep(args: &Args) {
    // `sweep` grids over --rates/--schedulers; the singular flags
    // would be silently overridden per cell, so reject the likely typo
    // instead of running a grid the user never asked for.
    if args.get("rate").is_some() {
        die("`sweep` takes --rates R1,R2,.. (plural), not --rate");
    }
    if args.get("scheduler").is_some() {
        die("`sweep` takes --schedulers N1,N2,.. (plural), not --scheduler");
    }
    if args.get("fleet").is_some() && args.get("fleets").is_some() {
        die("pass either --fleet (one fleet for every cell) or --fleets F1;F2;.. \
             (grid axis), not both");
    }
    if args.get("predictor").is_some() && args.get("predictors").is_some() {
        die("pass either --predictor (one predictor for every cell) or \
             --predictors P1;P2;.. (grid axis), not both");
    }
    let rates: Vec<f64> = args
        .get_or("rates", "8,16,32")
        .split(',')
        .map(|s| s.trim().parse().unwrap_or_else(|_| die("--rates must be numbers, e.g. 8,16,32")))
        .collect();
    // `;` separates schedulers whose names contain commas (custom
    // axis specs); plain lists use `,`.
    let scheds_raw = args.get_or("schedulers", "cascade,vllm");
    let sep = if scheds_raw.contains(';') { ';' } else { ',' };
    let schedulers: Vec<String> =
        scheds_raw.split(sep).map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if rates.is_empty() || schedulers.is_empty() {
        die("sweep needs at least one rate and one scheduler");
    }
    // Fail fast on any unresolvable scheduler *before* running grid
    // cells — otherwise a comma-split `custom:` spec could silently
    // run a policy the user never asked for and only error later.
    for name in &schedulers {
        if let Err(e) = PolicySpec::resolve(name) {
            if sep == ',' && scheds_raw.contains("custom:") {
                die(&format!(
                    "{e}\nhint: `--schedulers` was split on `,`, which also appears inside \
                     custom: specs — separate schedulers with `;` instead"
                ));
            }
            die(&e.to_string());
        }
    }

    // The fleet grid axis: `;`-separated fleet strings (fleet specs
    // contain commas).  Absent -> a single "legacy" cell with no fleet.
    let fleets: Vec<Option<String>> = match args.get("fleets") {
        Some(s) => s
            .split(';')
            .map(str::trim)
            .filter(|f| !f.is_empty())
            .map(|f| Some(f.to_string()))
            .collect(),
        None => vec![None],
    };

    // The predictor grid axis — the QoE-vs-accuracy robustness sweep,
    // e.g. `--predictors "oracle;noisy:0.2;noisy:0.5;bucket:0.7;ltr:0.8"`.
    // Absent -> a single legacy cell (whatever --predictor/config set).
    let predictors: Vec<Option<String>> = match args.get("predictors") {
        Some(s) => s
            .split(';')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|p| Some(p.to_string()))
            .collect(),
        None => vec![None],
    };

    // One resolved builder (config file read, workload parsed) shared
    // by every cell; each cell only overrides rate + scheduler (+
    // fleet when sweeping fleets).  Cells are independent experiments,
    // so they run across `--jobs` worker threads (default: available
    // parallelism); the table is byte-identical for any job count.
    let base = builder_from_args(args);
    let spec = sweep::SweepSpec {
        rates,
        schedulers,
        fleets,
        predictors,
        // One fault schedule for every cell: churn compares schedulers
        // under identical failures, so it is a spec, not a grid axis.
        churn: args.get("churn").map(|s| s.to_string()),
        jobs: args.get_usize("jobs", sweep::default_jobs()),
    };
    match sweep::run_sweep(&base, &spec) {
        Ok(table) => println!("{table}"),
        Err(e) => die(&e),
    }
}

fn cmd_plan(args: &Args) {
    let model = model_by_name_or_die(&args.get_or("model", "Llama-3.2-3B"));
    let gpu = gpu_by_name_or_die(&args.get_or("gpu", "H20"));
    let e = args.get_usize("instances", 16);
    let n_req = args.get_usize("requests", 5000);
    let seed = args.get_u64("seed", 42);

    let am = AttentionModel::new(gpu, model);
    let (qoe_model, _) = qoe::profile_and_fit(&am, 64, 131_072, 512);
    let reqs = workload::generate(&ShareGptLike::default(), 10.0, n_req, seed);
    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    let planner = Planner::new(
        qoe_model,
        MigrationCost::new(model.kv_bytes_per_token() as f64, 450e9),
    );

    let t0 = std::time::Instant::now();
    let dp = planner.plan_dp(&hist, e);
    let dp_t = t0.elapsed();
    let t0 = std::time::Instant::now();
    let heur = planner.plan_heuristic(&hist, e);
    let heur_t = t0.elapsed();

    println!("planning {} instances over {} requests ({})", e, n_req, model.name);
    println!("exact DP   ({dp_t:?}):");
    for s in &dp.stages {
        println!("  [{:>7}, {:>7})  x{}", s.lo, s.hi, s.n_instances);
    }
    println!("heuristic  ({heur_t:?}):");
    for s in &heur.stages {
        println!("  [{:>7}, {:>7})  x{}", s.lo, s.hi, s.n_instances);
    }
    println!(
        "quality: dp {:.4}, heuristic {:.4}",
        dp.predicted_quality,
        planner.pipeline_quality(&hist, &heur)
    );
}

fn cmd_fit(args: &Args) {
    let model = model_by_name_or_die(&args.get_or("model", "Llama-3.2-3B"));
    let gpu = gpu_by_name_or_die(&args.get_or("gpu", "H20"));
    let am = AttentionModel::new(gpu, model);
    let (qoe_model, samples) = qoe::profile_and_fit(&am, 64, 131_072, 512);
    println!("QoE fit for {} on {} ({} samples)", model.name, gpu.name, samples.len());
    println!("D = {:?}", qoe_model.d);
    let errs = qoe::relative_errors(&qoe_model, &samples);
    println!("in-sample MAE {:.2}%", 100.0 * qoe::mean_abs_rel_error(&errs));
}

fn cmd_gen_trace(args: &Args) {
    let out = args.get_or("out", "trace.csv");
    let rate = args.get_f64("rate", 8.0);
    let n = args.get_usize("requests", 2000);
    let seed = args.get_u64("seed", 42);
    let reqs = workload::generate(&ShareGptLike::default(), rate, n, seed);
    workload::save_csv(&out, &reqs).expect("write trace");
    println!("wrote {n} requests to {out}");
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) {
    eprintln!(
        "`serve` drives the real PJRT path and needs the `pjrt` feature:\n  \
         cargo run --release --features pjrt -- serve"
    );
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) {
    use cascade_infer::server::{ServeRequest, Server, ServerConfig};
    let dir = args.get_or("artifacts", "artifacts");
    let n_req = args.get_usize("requests", 12);
    let seed = args.get_u64("seed", 7);

    println!("starting real-path server over {dir} (compiling executables)...");
    let cfg = ServerConfig::new(dir);
    let mut server = Server::start(cfg).expect("server starts");
    let mut rng = cascade_infer::sim::Rng::new(seed);
    let t0 = std::time::Instant::now();
    for id in 0..n_req {
        let plen = 4 + rng.next_range(28) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.next_range(256) as i32).collect();
        server.submit(ServeRequest { id: id as u64, prompt, max_new_tokens: 24 });
    }
    let responses = server.collect(n_req);
    let wall = t0.elapsed().as_secs_f64();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let migrated = responses.iter().filter(|r| r.served_by.len() > 1).count();
    println!("served {n_req} requests, {total_tokens} tokens in {wall:.2}s");
    println!("throughput {:.1} tok/s, {migrated} requests migrated", total_tokens as f64 / wall);
    for r in responses.iter().take(3) {
        println!(
            "  req {}: ttft {:?}, e2e {:?}, path {:?}",
            r.id,
            r.ttft(),
            r.e2e(),
            r.served_by
        );
    }
    server.shutdown();
}
