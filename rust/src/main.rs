//! `cascade-infer` — leader entrypoint.
//!
//! Subcommands drive the two halves of the reproduction:
//! * `sim` / `plan` / `fit` / `gen-trace` — the 16-instance simulated
//!   testbed used by every figure,
//! * `serve` — the real PJRT path over the AOT artifacts.

use cascade_infer::cli::{scheduler_by_name, Args, USAGE};
use cascade_infer::cluster::{run_experiment, ClusterConfig, SchedulerKind};
use cascade_infer::coordinator::plan::{MigrationCost, Planner};
use cascade_infer::gpu::GpuProfile;
use cascade_infer::kernelmodel::AttentionModel;
use cascade_infer::metrics::Slo;
use cascade_infer::models;
use cascade_infer::qoe;
use cascade_infer::workload::{self, LengthHistogram, ShareGptLike};

fn gpu_by_name(name: &str) -> GpuProfile {
    match name.to_ascii_uppercase().as_str() {
        "L40" => GpuProfile::L40,
        "H100" => GpuProfile::H100,
        _ => GpuProfile::H20,
    }
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "sim" => cmd_sim(&args),
        "plan" => cmd_plan(&args),
        "fit" => cmd_fit(&args),
        "gen-trace" => cmd_gen_trace(&args),
        "serve" => cmd_serve(&args),
        _ => println!("{USAGE}"),
    }
}

fn cmd_sim(args: &Args) {
    let model = models::by_name(&args.get_or("model", "Llama-3.2-3B"))
        .expect("unknown model; see models::paper_zoo()");
    let gpu = gpu_by_name(&args.get_or("gpu", "H20"));
    let n = args.get_usize("instances", 16);
    let rate = args.get_f64("rate", 8.0);
    let n_req = args.get_usize("requests", 2000);
    let seed = args.get_u64("seed", 42);
    let sched = scheduler_by_name(&args.get_or("scheduler", "cascade"))
        .expect("unknown scheduler");

    let reqs = workload::generate(&ShareGptLike::default(), rate, n_req, seed);
    let mut cfg = ClusterConfig::new(gpu, model, n, sched);
    if sched == SchedulerKind::LlumnixLike {
        cfg.engine_speed = 1.25; // Llumnix's newer engine (§6.2 Fig. 8)
    }
    println!(
        "sim: {} x{} on {}, rate {:.1} req/s, {} requests, scheduler {}",
        model.name, n, gpu.name, rate, n_req, sched.name()
    );
    let t0 = std::time::Instant::now();
    let (report, stats) = run_experiment(cfg, &reqs);
    println!("wall time        {:.2}s", t0.elapsed().as_secs_f64());
    println!("completed        {}", report.records.len());
    println!("mean TTFT        {:.4}s   p95 {:.4}s", report.mean_ttft(), report.p95_ttft());
    println!("mean TPOT        {:.5}s   p95 {:.5}s", report.mean_tpot(), report.p95_tpot());
    println!("norm latency     {:.5}s/token", report.mean_normalized_latency());
    println!("throughput       {:.1} tok/s", report.throughput_tokens_per_s());
    let slo = Slo { ttft: 1.0, tpot: 0.1 };
    println!("SLO(1s,100ms)    {:.1}%", 100.0 * report.slo_attainment(slo));
    println!(
        "migrations       {} ({} skipped), preemptions {}",
        stats.migrations, stats.migrations_skipped, stats.preemptions
    );
    println!("stages           {:?}", stats.stages.iter().map(|s| s.len()).collect::<Vec<_>>());
    println!("boundaries       {:?}", stats.final_boundaries);
}

fn cmd_plan(args: &Args) {
    let model = models::by_name(&args.get_or("model", "Llama-3.2-3B")).expect("unknown model");
    let gpu = gpu_by_name(&args.get_or("gpu", "H20"));
    let e = args.get_usize("instances", 16);
    let n_req = args.get_usize("requests", 5000);
    let seed = args.get_u64("seed", 42);

    let am = AttentionModel::new(gpu, model);
    let (qoe_model, _) = qoe::profile_and_fit(&am, 64, 131_072, 512);
    let reqs = workload::generate(&ShareGptLike::default(), 10.0, n_req, seed);
    let hist = LengthHistogram::from_requests(&reqs, 131_072);
    let planner = Planner::new(
        qoe_model,
        MigrationCost::new(model.kv_bytes_per_token() as f64, 450e9),
    );

    let t0 = std::time::Instant::now();
    let dp = planner.plan_dp(&hist, e);
    let dp_t = t0.elapsed();
    let t0 = std::time::Instant::now();
    let heur = planner.plan_heuristic(&hist, e);
    let heur_t = t0.elapsed();

    println!("planning {} instances over {} requests ({})", e, n_req, model.name);
    println!("exact DP   ({dp_t:?}):");
    for s in &dp.stages {
        println!("  [{:>7}, {:>7})  x{}", s.lo, s.hi, s.n_instances);
    }
    println!("heuristic  ({heur_t:?}):");
    for s in &heur.stages {
        println!("  [{:>7}, {:>7})  x{}", s.lo, s.hi, s.n_instances);
    }
    println!(
        "quality: dp {:.4}, heuristic {:.4}",
        dp.predicted_quality,
        planner.pipeline_quality(&hist, &heur)
    );
}

fn cmd_fit(args: &Args) {
    let model = models::by_name(&args.get_or("model", "Llama-3.2-3B")).expect("unknown model");
    let gpu = gpu_by_name(&args.get_or("gpu", "H20"));
    let am = AttentionModel::new(gpu, model);
    let (qoe_model, samples) = qoe::profile_and_fit(&am, 64, 131_072, 512);
    println!("QoE fit for {} on {} ({} samples)", model.name, gpu.name, samples.len());
    println!("D = {:?}", qoe_model.d);
    let errs = qoe::relative_errors(&qoe_model, &samples);
    println!("in-sample MAE {:.2}%", 100.0 * qoe::mean_abs_rel_error(&errs));
}

fn cmd_gen_trace(args: &Args) {
    let out = args.get_or("out", "trace.csv");
    let rate = args.get_f64("rate", 8.0);
    let n = args.get_usize("requests", 2000);
    let seed = args.get_u64("seed", 42);
    let reqs = workload::generate(&ShareGptLike::default(), rate, n, seed);
    workload::save_csv(&out, &reqs).expect("write trace");
    println!("wrote {n} requests to {out}");
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) {
    eprintln!(
        "`serve` drives the real PJRT path and needs the `pjrt` feature:\n  \
         cargo run --release --features pjrt -- serve"
    );
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) {
    use cascade_infer::server::{ServeRequest, Server, ServerConfig};
    let dir = args.get_or("artifacts", "artifacts");
    let n_req = args.get_usize("requests", 12);
    let seed = args.get_u64("seed", 7);

    println!("starting real-path server over {dir} (compiling executables)...");
    let cfg = ServerConfig::new(dir);
    let mut server = Server::start(cfg).expect("server starts");
    let mut rng = cascade_infer::sim::Rng::new(seed);
    let t0 = std::time::Instant::now();
    for id in 0..n_req {
        let plen = 4 + rng.next_range(28) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.next_range(256) as i32).collect();
        server.submit(ServeRequest { id: id as u64, prompt, max_new_tokens: 24 });
    }
    let responses = server.collect(n_req);
    let wall = t0.elapsed().as_secs_f64();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let migrated = responses.iter().filter(|r| r.served_by.len() > 1).count();
    println!("served {n_req} requests, {total_tokens} tokens in {wall:.2}s");
    println!("throughput {:.1} tok/s, {migrated} requests migrated", total_tokens as f64 / wall);
    for r in responses.iter().take(3) {
        println!(
            "  req {}: ttft {:?}, e2e {:?}, path {:?}",
            r.id,
            r.ttft(),
            r.e2e(),
            r.served_by
        );
    }
    server.shutdown();
}
