//! GPU and interconnect profiles — the simulated testbeds of §6.1.
//!
//! The paper evaluates on two 16-GPU testbeds (NVLink H20 141 GB and
//! PCIe L40 48 GB, 400 Gbps CX-7 NICs).  Neither exists here, so each
//! device is reduced to the handful of numbers the attention-backend
//! cost model ([`crate::kernelmodel`]) and the migration subsystem
//! ([`crate::coordinator::migrate`]) actually consume: SM count, HBM
//! bandwidth, memory capacity, dense-FP16 throughput, and link
//! bandwidths.  Published datasheet values are used throughout.

/// A GPU device profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    /// Streaming multiprocessors (the unit of kernel-block parallelism).
    pub sm_count: u32,
    /// HBM/GDDR bandwidth in bytes/s.
    pub hbm_bytes_per_s: f64,
    /// Device memory in bytes.
    pub mem_bytes: u64,
    /// Dense FP16/BF16 tensor throughput in FLOP/s (no sparsity).
    pub fp16_flops: f64,
    /// Fraction of peak FLOPs a well-tuned GEMM sustains.
    pub mfu: f64,
    /// Fixed per-kernel-launch overhead (seconds).
    pub launch_overhead_s: f64,
}

impl GpuProfile {
    /// NVIDIA H20: 78 SMs, 141 GB HBM3e @ 4.0 TB/s, 148 TFLOPs FP16.
    /// (The H20 trades compute for memory — exactly why the paper's
    /// decode workloads are attention/memory dominated on it.)
    pub const H20: GpuProfile = GpuProfile {
        name: "H20",
        sm_count: 78,
        hbm_bytes_per_s: 4.0e12,
        mem_bytes: 141 * GIB,
        fp16_flops: 148.0e12,
        mfu: 0.70,
        launch_overhead_s: 8.0e-6,
    };

    /// NVIDIA L40: 142 SMs, 48 GB GDDR6 @ 864 GB/s, 181 TFLOPs FP16.
    pub const L40: GpuProfile = GpuProfile {
        name: "L40",
        sm_count: 142,
        hbm_bytes_per_s: 0.864e12,
        mem_bytes: 48 * GIB,
        fp16_flops: 181.0e12,
        mfu: 0.65,
        launch_overhead_s: 8.0e-6,
    };

    /// NVIDIA H100 SXM: used for the paper's §2.2 motivation numbers.
    pub const H100: GpuProfile = GpuProfile {
        name: "H100",
        sm_count: 132,
        hbm_bytes_per_s: 3.35e12,
        mem_bytes: 80 * GIB,
        fp16_flops: 989.0e12,
        mfu: 0.75,
        launch_overhead_s: 8.0e-6,
    };

    /// Valid `--gpu` names, in presentation order.
    pub const NAMES: [&'static str; 3] = ["H20", "L40", "H100"];

    /// Resolve a profile by (case-insensitive) name.  Returns `None`
    /// for unknown names — callers decide whether that is a hard error
    /// (the CLI lists [`GpuProfile::NAMES`]) instead of the old silent
    /// fallback to H20.
    pub fn by_name(name: &str) -> Option<GpuProfile> {
        match name.to_ascii_uppercase().as_str() {
            "H20" => Some(GpuProfile::H20),
            "L40" => Some(GpuProfile::L40),
            "H100" => Some(GpuProfile::H100),
            _ => None,
        }
    }

    /// Effective GEMM throughput (FLOP/s) after the MFU haircut.
    pub fn effective_flops(&self) -> f64 {
        self.fp16_flops * self.mfu
    }

    /// Per-SM share of memory bandwidth (bytes/s) when all SMs stream.
    pub fn bw_per_sm(&self) -> f64 {
        self.hbm_bytes_per_s / self.sm_count as f64
    }
}

pub const GIB: u64 = 1024 * 1024 * 1024;

/// Link technology between two instances.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Same node, NVLink (H20 testbed): ~450 GB/s unidirectional.
    NvLink,
    /// Same node, PCIe Gen4 x16 (L40 testbed): ~25 GB/s effective.
    Pcie,
    /// Cross node over 400 Gbps ConnectX-7 RDMA: ~45 GB/s effective.
    Rdma,
}

impl LinkKind {
    pub fn bytes_per_s(&self) -> f64 {
        match self {
            LinkKind::NvLink => 450.0e9,
            LinkKind::Pcie => 25.0e9,
            LinkKind::Rdma => 45.0e9,
        }
    }

    /// One-way small-message latency (seconds) for control traffic.
    pub fn latency_s(&self) -> f64 {
        match self {
            LinkKind::NvLink => 5.0e-6,
            LinkKind::Pcie => 10.0e-6,
            LinkKind::Rdma => 15.0e-6,
        }
    }
}

/// Physical placement of instances onto nodes, so the migration
/// subsystem can distinguish intra-node from inter-node transfers
/// (§5: "placing instances of adjacent pipeline stages on the same
/// node whenever possible").
#[derive(Debug, Clone)]
pub struct Topology {
    pub gpus_per_node: usize,
    pub intra_node: LinkKind,
    pub inter_node: LinkKind,
    /// node index per instance id.
    pub node_of: Vec<usize>,
}

impl Topology {
    /// Sequential fill: instance i lands on node i / gpus_per_node.
    /// Because pipeline planning emits stages in length order and
    /// assigns instance ids contiguously, adjacent stages naturally
    /// co-locate — the §5 placement optimization.
    pub fn sequential(n_instances: usize, gpus_per_node: usize, intra: LinkKind) -> Self {
        assert!(gpus_per_node > 0);
        let node_of = (0..n_instances).map(|i| i / gpus_per_node).collect();
        Self { gpus_per_node, intra_node: intra, inter_node: LinkKind::Rdma, node_of }
    }

    /// The paper's H20 testbed: 2 nodes x 8 GPUs, NVLink intra-node.
    pub fn h20_testbed(n_instances: usize) -> Self {
        Self::sequential(n_instances, 8, LinkKind::NvLink)
    }

    /// The paper's L40 testbed: 2 nodes x 8 GPUs, PCIe intra-node.
    pub fn l40_testbed(n_instances: usize) -> Self {
        Self::sequential(n_instances, 8, LinkKind::Pcie)
    }

    pub fn link_between(&self, a: usize, b: usize) -> LinkKind {
        if self.node_of[a] == self.node_of[b] {
            self.intra_node
        } else {
            self.inter_node
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h20_is_memory_rich_compute_poor() {
        // The H20's FLOP/byte ratio is far below the H100's — the paper
        // picked it because decode is memory-bound there.
        let h20 = GpuProfile::H20.fp16_flops / GpuProfile::H20.hbm_bytes_per_s;
        let h100 = GpuProfile::H100.fp16_flops / GpuProfile::H100.hbm_bytes_per_s;
        assert!(h20 < h100 / 4.0);
    }

    #[test]
    fn l40_has_less_memory_than_h20() {
        assert!(GpuProfile::L40.mem_bytes < GpuProfile::H20.mem_bytes);
    }

    #[test]
    fn link_speeds_ordered() {
        assert!(LinkKind::NvLink.bytes_per_s() > LinkKind::Rdma.bytes_per_s());
        assert!(LinkKind::Rdma.bytes_per_s() > LinkKind::Pcie.bytes_per_s());
    }

    #[test]
    fn topology_sequential_co_locates_neighbors() {
        let t = Topology::h20_testbed(16);
        assert_eq!(t.node_of[0], t.node_of[7]);
        assert_ne!(t.node_of[7], t.node_of[8]);
        assert_eq!(t.link_between(0, 7), LinkKind::NvLink);
        assert_eq!(t.link_between(7, 8), LinkKind::Rdma);
    }

    #[test]
    fn bw_per_sm_partitions_total() {
        let g = GpuProfile::H20;
        let total = g.bw_per_sm() * g.sm_count as f64;
        assert!((total / g.hbm_bytes_per_s - 1.0).abs() < 1e-12);
    }
}
