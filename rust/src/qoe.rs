//! QoE model for requests and batches — paper §4.1, validated in Fig. 13.
//!
//! A batch B of n requests with input lengths I_i and current lengths
//! L_i has per-request quality (normalized latency)
//!
//! ```text
//! Q = D0*F0 + D1*F1 + D2*F2 + D3*F3 + D4*F4
//! F0 = 1, F1 = n, F2 = sum(I_i), F3 = sum(I_i^2), F4 = sum(L_i)
//! ```
//!
//! and batch quality `Q^B = n * Q` (Eq. 1).  The coefficients D_k are
//! fitted by least squares against profiled normalized latencies; this
//! module implements the feature extraction, the normal-equation OLS
//! solver, the profiling loop driver, and the validation-error
//! statistics that regenerate Fig. 13.

use crate::Tokens;

pub const N_FEATURES: usize = 5;

/// Batch-load features F0..F4 of Eq. (1).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Features(pub [f64; N_FEATURES]);

impl Features {
    /// Extract features from a batch described by (input_len, cur_len)
    /// pairs.  `cur_len` is the request's current total sequence length
    /// L_i (input + generated so far).
    pub fn from_batch(rows: &[(Tokens, Tokens)]) -> Self {
        let n = rows.len() as f64;
        let mut f2 = 0.0;
        let mut f3 = 0.0;
        let mut f4 = 0.0;
        for &(i, l) in rows {
            let fi = i as f64;
            f2 += fi;
            f3 += fi * fi;
            f4 += l as f64;
        }
        Features([1.0, n, f2, f3, f4])
    }

    /// Features of a decode-only batch (prefill terms from the inputs
    /// that *produced* the KV state).
    pub fn from_lens(input_lens: &[Tokens], cur_lens: &[Tokens]) -> Self {
        assert_eq!(input_lens.len(), cur_lens.len());
        let rows: Vec<(Tokens, Tokens)> =
            input_lens.iter().copied().zip(cur_lens.iter().copied()).collect();
        Self::from_batch(&rows)
    }
}

/// Fitted QoE coefficients D0..D4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QoeModel {
    pub d: [f64; N_FEATURES],
}

impl QoeModel {
    pub fn new(d: [f64; N_FEATURES]) -> Self {
        Self { d }
    }

    /// Per-request quality Q for a batch with features `f`.
    pub fn predict(&self, f: &Features) -> f64 {
        self.d.iter().zip(f.0.iter()).map(|(d, x)| d * x).sum()
    }

    /// Batch quality Q^B = n * Q (Eq. 1).
    pub fn batch_qoe(&self, f: &Features) -> f64 {
        f.0[1] * self.predict(f)
    }

    /// QoE of serving `rows` split evenly across `k` identical
    /// instances — the `(e-e') * Q^{n/(e-e')}` term of the §4.2 DP.
    ///
    /// Uses the paper's set-division approximation: an even split
    /// scales n, F2, F3, F4 by 1/k while F0 stays 1.
    pub fn split_batch_qoe(&self, f: &Features, k: usize) -> f64 {
        if k == 0 {
            return f64::INFINITY;
        }
        let k_inv = 1.0 / k as f64;
        let sub = Features([1.0, f.0[1] * k_inv, f.0[2] * k_inv, f.0[3] * k_inv, f.0[4] * k_inv]);
        k as f64 * self.batch_qoe(&sub)
    }
}

/// One profiling observation: features + measured normalized latency.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub features: Features,
    pub q: f64,
}

/// Ordinary least squares via the normal equations (X'X) d = X'y.
///
/// 5 unknowns — a dense 5x5 Gaussian elimination with partial pivoting
/// is exact enough and dependency-free.
pub fn fit(samples: &[Sample]) -> Option<QoeModel> {
    if samples.len() < N_FEATURES {
        return None;
    }
    let mut xtx = [[0.0f64; N_FEATURES]; N_FEATURES];
    let mut xty = [0.0f64; N_FEATURES];
    for s in samples {
        for i in 0..N_FEATURES {
            xty[i] += s.features.0[i] * s.q;
            for j in 0..N_FEATURES {
                xtx[i][j] += s.features.0[i] * s.features.0[j];
            }
        }
    }
    // Ridge epsilon (relative to each feature's scale) keeps the solve
    // stable when a feature is constant or nearly collinear across the
    // profile sweep.
    for (i, row) in xtx.iter_mut().enumerate() {
        row[i] += 1e-9 * row[i].abs().max(1.0);
    }
    solve5(xtx, xty).map(QoeModel::new)
}

/// Solve a 5x5 linear system with partial pivoting.
fn solve5(mut a: [[f64; N_FEATURES]; N_FEATURES], mut b: [f64; N_FEATURES]) -> Option<[f64; N_FEATURES]> {
    let n = N_FEATURES;
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-30 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        // Eliminate below.
        for r in col + 1..n {
            let f = a[r][col] / a[col][col];
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    // Back-substitute.
    let mut x = [0.0; N_FEATURES];
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in col + 1..n {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// Relative prediction errors |pred - obs| / obs for a validation set —
/// the Fig. 13 density is a histogram of `(pred - obs) / obs`.
pub fn relative_errors(model: &QoeModel, validation: &[Sample]) -> Vec<f64> {
    validation
        .iter()
        .filter(|s| s.q.abs() > 1e-12)
        .map(|s| (model.predict(&s.features) - s.q) / s.q)
        .collect()
}

/// Mean absolute relative error (the paper reports 8.9% for the QoE
/// model vs 64% for a static mean predictor).
pub fn mean_abs_rel_error(errors: &[f64]) -> f64 {
    if errors.is_empty() {
        return 0.0;
    }
    errors.iter().map(|e| e.abs()).sum::<f64>() / errors.len() as f64
}

/// The static baseline of Fig. 13: always predict the fitting-set mean.
pub fn static_baseline_errors(fit_set: &[Sample], validation: &[Sample]) -> Vec<f64> {
    let mean = fit_set.iter().map(|s| s.q).sum::<f64>() / fit_set.len().max(1) as f64;
    validation
        .iter()
        .filter(|s| s.q.abs() > 1e-12)
        .map(|s| (mean - s.q) / s.q)
        .collect()
}

/// Profile the (simulated) hardware and fit the QoE model — the §4.1
/// calibration loop.
///
/// Mirrors the paper's procedure: partition lengths into exponential
/// buckets, sweep batch sizes 1, 2, 4, ... per bucket, measure each
/// configuration's normalized latency (here: priced by the analytic
/// attention cost model, i.e. "running" the profile on the simulated
/// GPU), extract batch features, and least-squares fit D0..D4.
pub fn profile_and_fit(
    m: &crate::kernelmodel::AttentionModel,
    min_len: Tokens,
    max_len: Tokens,
    max_batch: usize,
) -> (QoeModel, Vec<Sample>) {
    let mut samples = Vec::new();
    for (lo, hi) in length_buckets(min_len, max_len) {
        let len = (lo + hi) / 2;
        // Sweep input/output splits so F2/F3 (prefill terms) are not
        // collinear with F4 (decode term) in the design matrix.
        for frac in [0.25, 0.5, 0.75] {
            let input = ((len as f64 * frac) as Tokens).max(1);
            let output = (len - input).max(1);
            let mut b = 1usize;
            while b <= max_batch {
                let lens = vec![len; b];
                let t_iter = m.decode_iteration_latency(&lens);
                let t_prefill = m.prefill_latency(input);
                // Normalized latency: end-to-end per output token under
                // closed-loop batch-B steady state.
                let q = t_iter + t_prefill / output as f64;
                let rows: Vec<(Tokens, Tokens)> = vec![(input, len); b];
                samples.push(Sample { features: Features::from_batch(&rows), q });
                b *= 2;
            }
        }
    }
    let model = fit(&samples).expect("profiling produced a fittable design");
    (model, samples)
}

/// Exponentially growing length buckets used by the profiling sweep
/// (§4.1: "[100,200), [200,400), [400,800), ...").
pub fn length_buckets(min_len: Tokens, max_len: Tokens) -> Vec<(Tokens, Tokens)> {
    let mut out = Vec::new();
    let mut lo = min_len.max(1);
    while lo < max_len {
        let hi = (lo * 2).min(max_len);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn features_hand_computed() {
        let f = Features::from_batch(&[(10, 20), (30, 50)]);
        assert_eq!(f.0, [1.0, 2.0, 40.0, 1000.0, 70.0]);
    }

    #[test]
    fn fit_recovers_exact_linear_model() {
        let truth = QoeModel::new([0.5, 0.01, 2e-4, 3e-8, 5e-5]);
        let mut rng = Rng::new(9);
        let mut samples = Vec::new();
        for _ in 0..200 {
            let n = 1 + rng.next_range(64);
            let rows: Vec<(u64, u64)> = (0..n)
                .map(|_| {
                    let i = 50 + rng.next_range(4000);
                    (i, i + rng.next_range(1000))
                })
                .collect();
            let f = Features::from_batch(&rows);
            samples.push(Sample { features: f, q: truth.predict(&f) });
        }
        let fitted = fit(&samples).unwrap();
        // The relative ridge introduces O(1e-5) bias — accept that.
        for (a, b) in fitted.d.iter().zip(truth.d.iter()) {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn fit_with_noise_beats_static_baseline() {
        let truth = QoeModel::new([1.0, 0.05, 1e-4, 2e-9, 8e-5]);
        let mut rng = Rng::new(10);
        let mut make = |n_samples: usize| -> Vec<Sample> {
            (0..n_samples)
                .map(|_| {
                    let n = 1 + rng.next_range(128);
                    let rows: Vec<(u64, u64)> = (0..n)
                        .map(|_| {
                            let i = 100 + rng.next_range(8000);
                            (i, i + rng.next_range(2000))
                        })
                        .collect();
                    let f = Features::from_batch(&rows);
                    let noise = 1.0 + 0.05 * rng.normal();
                    Sample { features: f, q: truth.predict(&f) * noise }
                })
                .collect()
        };
        let fit_set = make(400);
        let val_set = make(200);
        let model = fit(&fit_set).unwrap();
        let model_err = mean_abs_rel_error(&relative_errors(&model, &val_set));
        let static_err = mean_abs_rel_error(&static_baseline_errors(&fit_set, &val_set));
        assert!(model_err < 0.10, "model err {model_err}");
        assert!(static_err > 2.0 * model_err, "static {static_err} vs model {model_err}");
    }

    #[test]
    fn batch_qoe_is_n_times_request_qoe() {
        let m = QoeModel::new([1.0, 2.0, 0.0, 0.0, 0.0]);
        let f = Features::from_batch(&[(1, 1); 8]);
        assert!((m.batch_qoe(&f) - 8.0 * m.predict(&f)).abs() < 1e-12);
    }

    #[test]
    fn split_batch_reduces_qoe_for_load_terms() {
        // Splitting work over more instances must not increase QoE when
        // the per-batch constant D0 is negligible.
        let m = QoeModel::new([1e-6, 0.01, 1e-4, 1e-9, 1e-4]);
        let rows: Vec<(u64, u64)> = (0..64).map(|i| (100 + i, 200 + i)).collect();
        let f = Features::from_batch(&rows);
        let q1 = m.split_batch_qoe(&f, 1);
        let q2 = m.split_batch_qoe(&f, 2);
        let q4 = m.split_batch_qoe(&f, 4);
        assert!(q2 < q1 && q4 < q2, "{q1} {q2} {q4}");
    }

    #[test]
    fn split_by_zero_is_infinite() {
        let m = QoeModel::new([1.0; 5]);
        let f = Features::from_batch(&[(1, 1)]);
        assert!(m.split_batch_qoe(&f, 0).is_infinite());
    }

    #[test]
    fn buckets_are_exponential_and_cover() {
        let b = length_buckets(100, 1600);
        assert_eq!(b, vec![(100, 200), (200, 400), (400, 800), (800, 1600)]);
        let b = length_buckets(100, 1000);
        assert_eq!(b.last().unwrap().1, 1000);
    }

    #[test]
    fn profile_and_fit_predicts_cost_model() {
        use crate::gpu::GpuProfile;
        use crate::kernelmodel::AttentionModel;
        use crate::models::LLAMA_3B;
        let m = AttentionModel::new(GpuProfile::H20, LLAMA_3B);
        let (qoe, samples) = profile_and_fit(&m, 100, 131_072, 512);
        assert!(samples.len() > 30);
        // In-sample relative error should be modest (the true cost is
        // only piecewise-linear in the features).
        let errs = relative_errors(&qoe, &samples);
        let mae = mean_abs_rel_error(&errs);
        assert!(mae < 0.35, "profiling fit MAE {mae}");
        // And it must beat the static-mean baseline clearly (Fig. 13).
        let static_mae = mean_abs_rel_error(&static_baseline_errors(&samples, &samples));
        assert!(mae < 0.5 * static_mae, "model {mae} vs static {static_mae}");
    }

    #[test]
    fn singular_system_returns_none() {
        // All-identical samples make X'X singular beyond the ridge eps;
        // the fit should still not blow up (ridge makes it solvable).
        let f = Features::from_batch(&[(10, 10)]);
        let samples = vec![Sample { features: f, q: 1.0 }; 10];
        let m = fit(&samples);
        assert!(m.is_some());
        // And prediction at the fitted point is close to 1.0.
        assert!((m.unwrap().predict(&f) - 1.0).abs() < 1e-3);
    }
}
